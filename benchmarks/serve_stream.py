"""Open-loop goodput benchmark: streaming vs drain-the-bucket serving
(DESIGN.md §12).

Continuous batching only earns its place if it moves the serving curves, so
this benchmark replays the *same* open-loop arrival trace — compound
Poisson: sweep-shaped bursts at Poisson epochs, the native traffic of a
service whose CLI submits instance lists — through both front doors and
compares them where it matters: at offered loads above capacity, where a
batch-and-drain scheduler turns each burst into one wide mixed batch that
convoys behind stragglers and burns whole device-chunks on lanes that
already finished:

* **stream** — :class:`~repro.serve.StreamingAnnealService`: plateau-chunk
  scheduling quantum, slot backfill at chunk boundaries, deadline shedding;
* **drain** — accumulate arrivals while the one-shot service is busy, then
  ``solve()`` everything queued as one batch (the PR-7-era idiom).

Every request carries a ``target_cut`` taken from its own calibration
trace, so service demand varies per request *deterministically* — both
schedulers see identical work, and every streamed trace must be a bit-exact
prefix of its calibration trace (checked; this is live-lane bit-identity
measured in situ, not a statistical claim).

Metrics per (scheduler, load): p50/p99 latency (arrival → completion),
goodput (spin-cycles of deadline-met, target-reaching completions per
second of makespan), batch occupancy (live-lane chunks / slot chunks) and
shed/late counts.  Gates:

* smoke (CI): stream occupancy > drain occupancy at 2x load, prefix
  determinism, every non-shed stream result on time;
* full (nightly): stream goodput >= 1.5x drain goodput at the highest
  offered load.

Writes ``BENCH_serve_stream.json``; exits 1 on gate failure.

    python -m benchmarks.serve_stream            # full sweep (nightly)
    python -m benchmarks.serve_stream --smoke    # CI-sized
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import numpy as np

from repro.core import SSAHyperParams, gset
from repro.serve import (
    AnnealRequest,
    AnnealService,
    QueueFullError,
    StreamingAnnealService,
    StreamPolicy,
)

from .common import emit


def _pool(smoke):
    if smoke:
        return [gset.toroidal_grid(36, seed=s, name=f"t36s{s}")
                for s in range(4)]
    # Full mode must sit in the compute-bound regime: chunk wall time has
    # to *scale with batch width*, otherwise convoy waste is free (the
    # drain baseline idles lanes at zero marginal cost) and the scheduling
    # comparison measures nothing but the stream's per-quantum host
    # overhead.  Measured on this backend: n=100 is dispatch-dominated;
    # n=800 with 32 trials gives ~5x wall for a width-4 chunk vs width-1,
    # so an idle slot costs real seconds and the quantum bookkeeping
    # (sync + retire + splice, ~tens of ms) is noise.  One degree bucket
    # on purpose: a mixed pool lets solve() split every drain batch into
    # narrower per-bucket groups (right-sizing the baseline for free)
    # while the stream pays for two half-filled fixed-width tables — the
    # mixed-bucket path is exercised by the tests and the stream demo;
    # this benchmark isolates the scheduling discipline.
    return [gset.toroidal_grid(800, seed=s, name=f"t800s{s}")
            for s in range(6)]


def _hp(smoke):
    return (SSAHyperParams(n_trials=3, m_shot=8, tau=4, i0_min=1, i0_max=8)
            if smoke else SSAHyperParams(n_trials=32, m_shot=24, tau=16))


def calibrate(problems, hp, backend):
    """Solo full-budget solves: per-problem chunk traces (the ground truth
    every streamed lane must reproduce as a prefix) + a warm width-1 cache."""
    svc = AnnealService(backend=backend, min_bucket=16)
    entries = []
    for seed, p in enumerate(problems):
        r = svc.solve([AnnealRequest(problem=p, hp=hp, seed=seed)])[0]
        entries.append({"problem": p, "seed": seed,
                        "trace": [int(v) for v in r.chunk_best_cut]})
    return entries


def make_trace(entries, hp, n_requests, seed, interactive_frac=0.25,
               long_frac=0.3):
    """The request trace both schedulers replay: pool entry round-robin,
    deterministic bimodal demand — most requests carry a ``target_cut``
    from their own calibration trace (annealing saturates in a few chunks,
    so these retire early), while ``long_frac`` run untargeted to full
    budget.  Shorts stuck behind longs is exactly the convoy a
    drain-the-bucket scheduler pays and slot backfill does not."""
    rng = np.random.default_rng(seed)
    budget = len(entries[0]["trace"])
    out = []
    for i in range(n_requests):
        e = entries[i % len(entries)]
        if rng.random() < long_frac:
            target, need = None, budget  # full-budget batch lane
        else:
            # short lanes: targets from the *early* trace, so demand is
            # genuinely bimodal (a few chunks vs full budget) — uniform
            # targets blur the convoy the benchmark exists to expose
            k = int(rng.integers(1, max(2, budget // 4) + 1))
            target = e["trace"][k - 1]
            # demand = first chunk whose best reaches the target (<= k)
            need = next(j + 1 for j, v in enumerate(e["trace"]) if v >= target)
        out.append({
            "req": AnnealRequest(problem=e["problem"], hp=hp, seed=e["seed"],
                                 target_cut=target),
            "calib_trace": e["trace"],
            "chunks_needed": need,
            "work": float(hp.total_cycles) * hp.n_trials
            * e["problem"].n * need / budget,
            "priority": ("interactive" if rng.random() < interactive_frac
                         else "batch"),
        })
    return out


def poisson_arrivals(n, rate, seed, burst=1):
    """Compound-Poisson arrivals: bursts of ``burst`` simultaneous requests
    at Poisson epochs with aggregate rate ``rate``.  Bursts are the native
    traffic shape for this service — the CLI and the sweep examples submit
    a *list* of instances at once — and they are what separates the
    schedulers: a drain scheduler turns every burst into one wide mixed
    batch that convoys behind its slowest lane, while the stream retires
    the short lanes at chunk boundaries and backfills."""
    rng = np.random.default_rng(seed)
    epochs = np.cumsum(rng.exponential(burst / rate,
                                       size=(n + burst - 1) // burst))
    return np.repeat(epochs, burst)[:n]


def probe_service_time(entries, hp, backend, width):
    """Mean per-request wall seconds for a warm width-`width` batch solve —
    the capacity yardstick the offered-load factors are scaled against."""
    svc = AnnealService(backend=backend, min_bucket=16)
    reqs = [AnnealRequest(problem=entries[i % len(entries)]["problem"], hp=hp,
                          seed=entries[i % len(entries)]["seed"])
            for i in range(width)]
    svc.solve(reqs)                      # compile
    t0 = time.perf_counter()
    svc.solve(reqs)
    return (time.perf_counter() - t0) / width


def probe_stream_capacity(trace, backend, width):
    """Effective per-request service time of the streaming path (quantum
    overheads included) — the yardstick the offered loads and deadlines
    are scaled against.  Measured on a warm second pass."""
    ss = StreamingAnnealService(backend=backend, min_bucket=16,
                                policy=StreamPolicy(slots_per_table=width))
    items = [trace[i % len(trace)] for i in range(2 * width)]
    for it in items:
        ss.submit(it["req"])
    ss.run_until_idle()                  # compiles every table/width
    t0 = time.monotonic()
    tix = [ss.submit(it["req"]) for it in items]
    ss.run_until_idle()
    makespan = time.monotonic() - t0
    walls = [t.result(timeout=0).wall_s for t in tix]
    return (makespan / len(items), float(np.median(walls)),
            float(np.max(walls)))


def run_stream(trace, arrivals, deadline_s, backend, width):
    """Replay the arrival trace through the streaming front door."""
    ss = StreamingAnnealService(backend=backend, min_bucket=16,
                                policy=StreamPolicy(slots_per_table=width))
    # Warm every (table, width) executable the trace will need — a
    # long-lived server runs hot; compiles are not what we are measuring.
    warm = [ss.submit(trace[i % len(trace)]["req"])
            for i in range(min(2 * width, len(trace)))]
    ss.run_until_idle()
    for w in warm:
        w.result(timeout=0)
    occ0 = (ss.stats["stream_live_lane_chunks"],
            ss.stats["stream_slot_chunks"])

    ss.start(poll_s=0.001)
    records = []
    t0 = time.monotonic()
    try:
        for item, t_arr in zip(trace, arrivals):
            lag = t0 + t_arr - time.monotonic()
            if lag > 0:
                time.sleep(lag)
            req = dataclasses.replace(item["req"], deadline_s=deadline_s)
            try:
                ticket = ss.submit(req, priority=item["priority"])
            except QueueFullError:
                records.append({"item": item, "arrival": t_arr,
                                "rejected": True})
                continue
            records.append({"item": item, "arrival": t_arr,
                            "ticket": ticket})
        for r in records:
            if "ticket" in r:
                resp = r["ticket"].result(timeout=600.0)
                r["resp"] = resp
                # latency from the service's own clock (submit → done), not
                # from when this collection loop happens to look
                if resp.wall_s is not None:
                    r["latency"] = resp.wall_s
                    r["end"] = r["arrival"] + resp.wall_s
    finally:
        ss.stop()
    live, slot = (ss.stats["stream_live_lane_chunks"] - occ0[0],
                  ss.stats["stream_slot_chunks"] - occ0[1])
    return records, {"occupancy": live / max(1, slot),
                     **{k: int(v) for k, v in ss.stream_stats().items()
                        if k.startswith("stream_")}}


def run_drain(trace, arrivals, backend, width):
    """Drain-the-bucket baseline: batch everything queued, solve, repeat.

    Deadlines are accounted *externally* (completion - arrival), so the
    baseline is never penalised in-service — it simply pays the convoy:
    every batch runs until its slowest lane finishes or exhausts budget.
    """
    svc = AnnealService(backend=backend, min_bucket=16)
    fams = {}                            # one warm set per degree bucket
    for t in trace:
        fams.setdefault(t["req"].problem.name[0], []).append(t)
    for w in (1, 2, 4, 8):               # warm the pow2 width buckets...
        if w <= width:
            for fam in fams.values():    # ...for every family in the pool
                svc.solve([fam[i % len(fam)]["req"] for i in range(w)])
    occ0 = (svc.stats["live_lane_chunks"], svc.stats["slot_chunks"])

    records = [{"item": it, "arrival": t_arr}
               for it, t_arr in zip(trace, arrivals)]
    t0 = time.monotonic()
    i = 0
    while i < len(records):
        now = time.monotonic() - t0
        nxt = records[i]["arrival"]
        if now < nxt:
            time.sleep(nxt - now)
        now = time.monotonic() - t0
        j = i
        while j < len(records) and records[j]["arrival"] <= now:
            j += 1
        batch = records[i:j]
        # same compiled batch width as the stream's slot tables — the
        # comparison isolates scheduling, not device parallelism
        for k in range(0, len(batch), width):
            part = batch[k:k + width]
            resps = svc.solve([b["item"]["req"] for b in part])
            done = time.monotonic() - t0
            for b, resp in zip(part, resps):
                b["resp"] = resp
                b["latency"] = done - b["arrival"]
                b["end"] = done
        i = j
    live, slot = (svc.stats["live_lane_chunks"] - occ0[0],
                  svc.stats["slot_chunks"] - occ0[1])
    return records, {"occupancy": live / max(1, slot)}


def score(records, deadline_s):
    """Latency percentiles + goodput numerator over one replay."""
    lat, good_work, n_good, n_late, n_dropped = [], 0.0, 0, 0, 0
    makespan = 0.0
    for r in records:
        if r.get("rejected") or r.get("resp") is None:
            n_dropped += 1
            continue
        resp = r["resp"]
        if (resp.status in ("shed", "failed") or resp.result is None
                or "latency" not in r):
            n_dropped += 1
            continue
        latency = r["latency"]
        lat.append(latency)
        makespan = max(makespan, r["end"])
        tgt = r["item"]["req"].target_cut
        hit = (tgt is None                      # untargeted: full budget ran
               or int(np.max(np.asarray(resp.result.best_cut))) >= tgt)
        if hit and latency <= deadline_s:
            good_work += r["item"]["work"]
            n_good += 1
        else:
            n_late += 1
    return {
        "completed": len(lat),
        "on_time": n_good,
        "late": n_late,
        "dropped": n_dropped,
        "p50_s": float(np.percentile(lat, 50)) if lat else None,
        "p99_s": float(np.percentile(lat, 99)) if lat else None,
        "makespan_s": makespan,
        "goodput_cycles_per_s": good_work / makespan if makespan else 0.0,
    }


def check_prefix_determinism(records):
    """Every streamed lane's trace must be a prefix of its calibration
    trace — live-lane bit-identity, measured on the serving path."""
    bad = 0
    for r in records:
        resp = r.get("resp")
        if resp is None or resp.result is None:
            continue
        got = [int(v) for v in resp.chunk_best_cut]
        if got != r["item"]["calib_trace"][:len(got)]:
            bad += 1
    return bad


def run(smoke=False, json_path="BENCH_serve_stream.json", backend="sparse",
        seed=0):
    problems, hp = _pool(smoke), _hp(smoke)
    width = 2 if smoke else 8
    n_requests = 10 if smoke else 48
    loads = (2.0,) if smoke else (0.5, 2.0)

    entries = calibrate(problems, hp, backend)
    trace = make_trace(entries, hp, n_requests, seed)
    s_batch = probe_service_time(entries, hp, backend, width)
    s_stream, lane_p50, lane_max = probe_stream_capacity(
        trace, backend, width)
    # deadline: even a full-budget lane fits with queueing headroom
    deadline_s = max(2.0 * lane_max, 0.25)

    report = {"smoke": smoke, "backend": backend, "width": width,
              "n_requests": n_requests, "batched_service_time_s": s_batch,
              "stream_service_time_s": s_stream, "lane_p50_s": lane_p50,
              "lane_max_s": lane_max,
              "deadline_s": deadline_s, "loads": {}}
    failures = []

    for load in loads:
        # offered load relative to the measured streaming capacity
        rate = load / max(s_stream, 1e-6)
        arrivals = poisson_arrivals(n_requests, rate, seed, burst=width)
        srec, sstats = run_stream(trace, arrivals, deadline_s, backend, width)
        drec, dstats = run_drain(trace, arrivals, backend, width)
        s_score, d_score = score(srec, deadline_s), score(drec, deadline_s)
        bad_prefix = check_prefix_determinism(srec)
        if d_score["goodput_cycles_per_s"] > 0:
            ratio = (s_score["goodput_cycles_per_s"]
                     / d_score["goodput_cycles_per_s"])
        else:                            # drain served nothing on time
            ratio = float("inf") if s_score["goodput_cycles_per_s"] else 1.0
        ratio = min(ratio, 1e6)
        report["loads"][str(load)] = {
            "offered_rate_rps": rate,
            "stream": {**s_score, **sstats},
            "drain": {**d_score, **dstats},
            "goodput_ratio": ratio,
            "prefix_mismatches": bad_prefix,
        }
        emit(f"serve_stream/load{load}/stream",
             (s_score["p50_s"] or 0) * 1e6, s_score["goodput_cycles_per_s"])
        emit(f"serve_stream/load{load}/drain",
             (d_score["p50_s"] or 0) * 1e6, d_score["goodput_cycles_per_s"])
        emit(f"serve_stream/load{load}/goodput_ratio", 0.0, f"{ratio:.2f}")
        if bad_prefix:
            failures.append(
                f"load {load}: {bad_prefix} streamed traces diverged from "
                "their calibration traces (bit-identity broken)")

    high = report["loads"][str(loads[-1])]
    if smoke:
        # CI gate: the structural win must be visible even on a tiny run —
        # backfill keeps slots live while drain convoys behind stragglers.
        if high["stream"]["occupancy"] <= high["drain"]["occupancy"]:
            failures.append(
                f"smoke: stream occupancy {high['stream']['occupancy']:.3f} "
                f"<= drain occupancy {high['drain']['occupancy']:.3f}")
    else:
        if high["goodput_ratio"] < 1.5:
            failures.append(
                f"high load: goodput ratio {high['goodput_ratio']:.2f} < 1.5x")

    report["failures"] = failures
    report["ok"] = not failures
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI: small pool, one load point, occupancy gate")
    ap.add_argument("--backend", default="sparse")
    ap.add_argument("--json", default="BENCH_serve_stream.json")
    args = ap.parse_args()
    rep = run(smoke=args.smoke, json_path=args.json, backend=args.backend)
    if not rep["ok"]:
        for f in rep["failures"]:
            print(f"FAIL: {f}", file=sys.stderr)
        sys.exit(1)
