"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
one row per (arch × shape × mesh): the three terms, the dominant bound, the
MODEL/HLO flops ratio, and whether the step fits 16 GB/device.
"""
from __future__ import annotations

import glob
import json
import os

from .common import emit

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_records(dryrun_dir: str = DRYRUN_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(csv_prefix: str = "roofline", dryrun_dir: str = DRYRUN_DIR):
    recs = load_records(dryrun_dir)
    if not recs:
        emit(f"{csv_prefix}/missing", 0.0,
             "run `python -m repro.launch.dryrun --all` first")
        return []
    for r in recs:
        name = f"{csv_prefix}/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("rules", "baseline") != "baseline":
            name += f"/{r['rules']}"
        if r["status"] == "skipped":
            emit(name, 0.0, f"SKIP:{r['reason'][:60]}")
            continue
        if r["status"] != "ok":
            emit(name, 0.0, f"ERROR:{r.get('error', '?')[:60]}")
            continue
        if "t_compute_s" not in r:
            emit(name, 0.0, f"compiled_only;peak_GB={r['peak_bytes_per_device']/1e9:.2f}")
            continue
        bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        frac = r["t_compute_s"] / bound if bound else 0.0
        emit(
            name,
            bound * 1e6,
            f"dom={r['dominant']};tc_ms={r['t_compute_s']*1e3:.2f};"
            f"tm_ms={r['t_memory_s']*1e3:.2f};tx_ms={r['t_collective_s']*1e3:.2f};"
            f"roofline_frac={frac:.3f};useful={r['useful_flops_ratio'] or 0:.3f};"
            f"peak_GB={r['peak_bytes_per_device']/1e9:.2f};"
            f"fits16G={r['fits_hbm_16g']}",
        )
    return recs


if __name__ == "__main__":
    run()
