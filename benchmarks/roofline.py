"""Roofline analysis: dry-run table + the XNOR-popcount datapath gate.

Two entry points share this module:

* :func:`run` — the original table over experiments/dryrun/*.json artifacts
  (written by ``repro.launch.dryrun``): compute/memory/collective terms per
  (arch x shape x mesh) and the dominant bound.

* :func:`run_popcount` — the PR-7 perf gate.  For each instance class it
  measures steady-state spin-cycles/s of the dense backend under
  ``field_mode='popcount'`` vs ``field_mode='dense'`` (same backend, same
  bit-identical results — only the contraction arithmetic differs), plus the
  analytic bytes-moved-per-spin-update model that explains the gap: the f32
  matmul streams 4N bytes of J per spin update, the XNOR-popcount path
  streams (1 + n_bits) x N/8 bytes of sign/magnitude bitplanes — a 32x/
  (1+n_bits) traffic reduction, which is the whole point of making the
  packed bitplanes the *arithmetic* format.  Results land in
  ``BENCH_popcount.json``; ``--gate`` enforces

      * K2000-class (dense instance) popcount speedup >= GATE_K2000_MIN, and
      * no instance below GATE_FLOOR x dense (the >15% regression rule)

  Steady-state means: backend constructed once, the plateau chain jitted
  once, timing the warm calls — pack/compile are one-time costs and are
  excluded, exactly as in benchmarks.timing.  The gate instance uses a
  small trial count (Table-II-style): dense-J streaming amortizes over
  trials, so large batches flatter the matmul and would hide the datapath
  difference the FPGA cares about.
"""
from __future__ import annotations

import glob
import json
import os

import jax

from repro.core import gset
from repro.core.engine import make_backend, run_schedule, schedule_plateaus
from repro.core.ssa import SSAHyperParams
from repro.kernels.bitplane import adjacency_weight_bits

from .common import emit, time_call

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")

# Popcount-vs-dense gate thresholds (--gate).
GATE_K2000_MIN = 2.0   # required speedup on the dense (K2000-class) instance
GATE_FLOOR = 0.85      # no instance may regress spin-cycles/s by >15%

# (factory, hp) per instance class.  K2000 is the gate instance; G11 is the
# sparse torus; G81-class exercises the tiled regime (N > TILED_J_THRESHOLD:
# tiled-J slabs vs row-tiled popcount).  Smoke shrinks every class below the
# tile threshold so a CI cell finishes in seconds.
FULL_SPECS = {
    "G11": (lambda: gset.toroidal_grid(800, seed=11, name="G11"),
            SSAHyperParams(n_trials=4, m_shot=1, tau=30, i0_max=8)),
    "K2000": (lambda: gset.complete_graph(2000, seed=2000),
              SSAHyperParams(n_trials=4, m_shot=1, tau=30, i0_max=8)),
    "G81-class": (lambda: gset.toroidal_grid(6400, seed=81),
                  SSAHyperParams(n_trials=2, m_shot=1, tau=4, i0_max=2)),
}
SMOKE_SPECS = {
    "G11": (lambda: gset.toroidal_grid(256, seed=11),
            SSAHyperParams(n_trials=4, m_shot=1, tau=4, i0_max=4)),
    "K2000": (lambda: gset.complete_graph(256, seed=2000),
              SSAHyperParams(n_trials=4, m_shot=1, tau=4, i0_max=4)),
    "G81-class": (lambda: gset.toroidal_grid(576, seed=81),
                  SSAHyperParams(n_trials=2, m_shot=1, tau=4, i0_max=2)),
}


def load_records(dryrun_dir: str = DRYRUN_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(csv_prefix: str = "roofline", dryrun_dir: str = DRYRUN_DIR):
    recs = load_records(dryrun_dir)
    if not recs:
        emit(f"{csv_prefix}/missing", 0.0,
             "run `python -m repro.launch.dryrun --all` first")
        return []
    for r in recs:
        name = f"{csv_prefix}/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("rules", "baseline") != "baseline":
            name += f"/{r['rules']}"
        if r["status"] == "skipped":
            emit(name, 0.0, f"SKIP:{r['reason'][:60]}")
            continue
        if r["status"] != "ok":
            emit(name, 0.0, f"ERROR:{r.get('error', '?')[:60]}")
            continue
        if "t_compute_s" not in r:
            emit(name, 0.0, f"compiled_only;peak_GB={r['peak_bytes_per_device']/1e9:.2f}")
            continue
        bound = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        frac = r["t_compute_s"] / bound if bound else 0.0
        emit(
            name,
            bound * 1e6,
            f"dom={r['dominant']};tc_ms={r['t_compute_s']*1e3:.2f};"
            f"tm_ms={r['t_memory_s']*1e3:.2f};tx_ms={r['t_collective_s']*1e3:.2f};"
            f"roofline_frac={frac:.3f};useful={r['useful_flops_ratio'] or 0:.3f};"
            f"peak_GB={r['peak_bytes_per_device']/1e9:.2f};"
            f"fits16G={r['fits_hbm_16g']}",
        )
    return recs


def _steady_spin_cycles_per_s(model, hp, field_mode: str) -> tuple:
    """(spin-cycles/s, measured J-residency bytes, wall us) at steady state."""
    plateaus = schedule_plateaus(hp.schedule("hassa"))
    cycles = sum(p.length for p in plateaus)
    bk = make_backend(
        "dense", model, n_trials=hp.n_trials, n_rnd=hp.n_rnd,
        noise="xorshift", field_mode=field_mode,
    )
    if bk.field_mode == "popcount":
        pj = bk.packed_j
        j_bytes = int(pj.sign.nbytes + pj.mags.nbytes + pj.base.nbytes)
    elif bk.j_mode == "dense":
        j_bytes = int(bk.J.nbytes)
    else:  # tiled: the adjacency is what stays resident
        j_bytes = int(bk.nbr_idx.nbytes + bk.nbr_w.nbytes)
    state = bk.init_state(0)
    chain = jax.jit(
        lambda s: run_schedule(bk, plateaus, s, record="best",
                               track_energy=False)[0]
    )
    us = time_call(chain, state, warmup=1, iters=3)
    return cycles * hp.n_trials * model.n / (us * 1e-6), j_bytes, us


def run_popcount(
    smoke: bool = False,
    json_path: str = "BENCH_popcount.json",
    gate: bool = False,
    csv_prefix: str = "popcount",
):
    """Popcount-vs-dense spin-cycles/s bench; returns (report, failures)."""
    specs = SMOKE_SPECS if smoke else FULL_SPECS
    rows, failures = [], []
    for name, (factory, hp) in specs.items():
        model = factory().to_ising()
        dense_scs, dense_j, _ = _steady_spin_cycles_per_s(model, hp, "dense")
        pc_scs, pc_j, pc_us = _steady_spin_cycles_per_s(model, hp, "popcount")
        speedup = pc_scs / dense_scs
        # Analytic bytes-moved per spin update (the roofline model): the
        # matmul reads one f32 row of J, the popcount path one sign word
        # row + n_bits magnitude rows, 1 bit per coupling each.
        jb = adjacency_weight_bits(model.n, model.nbr_idx, model.nbr_w)
        bytes_dense = 4.0 * model.n
        bytes_pc = (1 + jb) * model.n / 8.0
        row = {
            "instance": name,
            "n": int(model.n),
            "n_trials": hp.n_trials,
            "cycles": int(sum(p.length
                              for p in schedule_plateaus(hp.schedule("hassa")))),
            "j_bits": int(jb),
            "dense_spin_cycles_per_s": dense_scs,
            "popcount_spin_cycles_per_s": pc_scs,
            "speedup": speedup,
            "j_bytes_dense": dense_j,
            "j_bytes_packed": pc_j,
            "model_bytes_per_spin_update_dense": bytes_dense,
            "model_bytes_per_spin_update_popcount": bytes_pc,
            "model_traffic_ratio": bytes_dense / bytes_pc,
        }
        rows.append(row)
        emit(
            f"{csv_prefix}/{name}/n{model.n}",
            pc_us,
            f"speedup={speedup:.2f};dense_scs={dense_scs:.3e};"
            f"pc_scs={pc_scs:.3e};traffic_ratio={bytes_dense/bytes_pc:.1f};"
            f"j_bytes={dense_j}->{pc_j}",
        )
        if gate and speedup < GATE_FLOOR:
            failures.append(
                f"{name}: popcount {speedup:.2f}x dense "
                f"(< {GATE_FLOOR}x regression floor)"
            )
    if gate and not smoke:
        k2000 = next(r for r in rows if r["instance"] == "K2000")
        if k2000["speedup"] < GATE_K2000_MIN:
            failures.append(
                f"K2000: popcount speedup {k2000['speedup']:.2f}x "
                f"< required {GATE_K2000_MIN}x"
            )
    report = {
        "smoke": smoke,
        "gate": {"k2000_min": GATE_K2000_MIN, "floor": GATE_FLOOR,
                 "enforced": gate, "failures": failures},
        "instances": rows,
    }
    with open(json_path, "w") as f:
        json.dump(report, f, indent=2)
    emit(f"{csv_prefix}/gate", 0.0,
         "PASS" if not failures else ";".join(failures))
    return report, failures


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced instance sizes (CI smoke cell)")
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 if the popcount speedup gate fails")
    ap.add_argument("--json", default="BENCH_popcount.json")
    ap.add_argument("--dryrun-table", action="store_true",
                    help="emit the dry-run artifact roofline table instead")
    args = ap.parse_args()
    if args.dryrun_table:
        run()
        sys.exit(0)
    _, failures = run_popcount(smoke=args.smoke, json_path=args.json,
                               gate=args.gate)
    if failures:
        print("GATE FAILURES:")
        for f in failures:
            print("  -", f)
        sys.exit(1)
