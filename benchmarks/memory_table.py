"""Paper Table IV: trajectory-memory usage, SSA (Eq. 5) vs HA-SSA (Eq. 6),
with equal cut values — analytic AND measured.

Table-II hyperparameters: N=800, I0 1→32 (6 plateaus), τ=100, m_shot=150:
SSA 0.48 Mb/iteration (72 Mb/trial) vs HA-SSA 0.08 Mb/iteration (12 Mb/trial)
→ 6×.  The measured columns size the buffers a reduced run *actually*
materializes (trajectory planes + live engine state, via
`repro.core.memory.measure_live_bytes` / `tree_device_bytes`), printed next
to the closed-form model.  The run **fails (exit 1)** when the measured
HA-SSA/SSA ratio regresses more than 15% below the analytic model — the
paper's headline is a gated runtime fact, not a formula.
"""
from __future__ import annotations

import sys

from repro.core import SSAHyperParams, anneal, gset, memory

from .common import emit

# Measured ratio may regress at most this far below the analytic model.
RATIO_TOLERANCE = 0.15


def run(csv_prefix: str = "table4_memory"):
    hp = SSAHyperParams()  # Table II
    n = 800
    m_ssa = memory.ssa_bits_per_iteration(n, hp)
    m_ha = memory.hassa_bits_per_iteration(n, hp)
    ratio = memory.memory_ratio(hp)
    emit(f"{csv_prefix}/ssa_bits_per_iter", 0.0, f"{m_ssa}")
    emit(f"{csv_prefix}/hassa_bits_per_iter", 0.0, f"{m_ha}")
    emit(f"{csv_prefix}/ssa_Mb_per_iter", 0.0, f"{m_ssa/1e6:.2f}")
    emit(f"{csv_prefix}/hassa_Mb_per_iter", 0.0, f"{m_ha/1e6:.2f}")
    emit(f"{csv_prefix}/ratio", 0.0, f"{ratio}x")
    emit(f"{csv_prefix}/ssa_Mb_per_trial", 0.0,
         f"{memory.bits_per_trial(n, hp, hardware_aware=False)/1e6:.0f}")
    emit(f"{csv_prefix}/hassa_Mb_per_trial", 0.0,
         f"{memory.bits_per_trial(n, hp, hardware_aware=True)/1e6:.0f}")

    # Serving-layer honesty column: the service pads N to its power-of-two
    # shape bucket, so each stored bitplane carries dead pad bits.  Report
    # the waste next to the Eq. (5)/(6) numbers so the memory comparison
    # stays valid under bucketing (N=800 → bucket 1024 → 28% of each plane).
    from repro.core.engine import bucket_n

    for n_i in (800, 1024, 2000):
        nb = bucket_n(n_i)
        pad_bits = memory.padding_overhead_bits_per_iteration(n_i, hp)
        frac = memory.padding_overhead_fraction(n_i)
        emit(f"{csv_prefix}/bucket_n{n_i}", 0.0, f"{nb}")
        emit(f"{csv_prefix}/pad_overhead_bits_per_iter_n{n_i}", 0.0, f"{pad_bits}")
        emit(f"{csv_prefix}/pad_overhead_pct_n{n_i}", 0.0, f"{100*frac:.1f}")

    # structural witness at reduced scale: the XLA output buffers ARE the
    # memory model (DESIGN.md §4, BRAM → buffer shapes)
    g = gset.load("G11")
    hp_small = SSAHyperParams(n_trials=2, m_shot=2)
    r_ha, ha_bytes = memory.measure_live_bytes(
        lambda: anneal(g, hp_small, seed=0, storage="i0max", record="traj")
    )
    r_ssa, ssa_bytes = memory.measure_live_bytes(
        lambda: anneal(g, hp_small, seed=0, storage="all", record="traj")
    )
    emit(f"{csv_prefix}/structural_ratio", 0.0,
         f"{r_ssa.stored_bits_per_iter // r_ha.stored_bits_per_iter}x")
    # equal-solution check (same stored-state subset contains the optimum)
    emit(f"{csv_prefix}/equal_best_cut", 0.0,
         str(int(r_ha.overall_best_cut) == int(r_ssa.overall_best_cut)))

    # -- measured columns, printed next to the analytic model --------------
    # Trajectory planes: the buffers the two storage policies actually
    # materialized (uint32 bitplane words, so ×8 = bits incl. word padding),
    # normalized per iteration AND per trial to match the Eq. (5)/(6)
    # columns above (traj shape is (m_shot, stored, T, Nw)).
    per_run = hp_small.m_shot * hp_small.n_trials
    meas_ssa_bits = 8 * r_ssa.traj.nbytes // per_run
    meas_ha_bits = 8 * r_ha.traj.nbytes // per_run
    measured_ratio = meas_ssa_bits / meas_ha_bits
    emit(f"{csv_prefix}/measured_ssa_bits_per_iter", 0.0, f"{meas_ssa_bits}")
    emit(f"{csv_prefix}/measured_hassa_bits_per_iter", 0.0, f"{meas_ha_bits}")
    emit(f"{csv_prefix}/measured_ratio", 0.0, f"{measured_ratio:.2f}x")
    emit(f"{csv_prefix}/analytic_ratio", 0.0, f"{ratio}x")
    emit(f"{csv_prefix}/measured_live_bytes_ssa_run", 0.0, f"{ssa_bytes}")
    emit(f"{csv_prefix}/measured_live_bytes_hassa_run", 0.0, f"{ha_bytes}")

    # Live engine state, dense vs packed bitplane layout (DESIGN.md §4):
    # what actually sits in HBM between plateau launches.
    from repro.core.engine import make_backend

    def state_bytes(layout):
        bk = make_backend(
            "sparse", g.to_ising(), n_trials=hp_small.n_trials,
            noise="xorshift", storage_layout=layout,
        )
        return memory.tree_device_bytes(bk.init_state(0))

    dense_state = state_bytes("dense")
    packed_state = state_bytes("packed")
    emit(f"{csv_prefix}/measured_state_bytes_dense", 0.0, f"{dense_state}")
    emit(f"{csv_prefix}/measured_state_bytes_packed", 0.0, f"{packed_state}")
    emit(f"{csv_prefix}/state_bytes_ratio", 0.0,
         f"{dense_state / packed_state:.2f}x")

    # Coupling-matrix residency: the earlier table rows only counted spin
    # planes + trajectory, silently omitting J itself — at N=800 the f32
    # matrix dwarfs everything above.  The popcount datapath keeps J as
    # sign/magnitude bitplanes (kernels.bitplane.PackedJ); report the
    # analytic codec size next to the bytes the two dense-backend
    # configurations actually pin on device.
    from repro.core.engine import make_backend as _mk
    from repro.kernels.bitplane import adjacency_weight_bits, packed_j_nbytes

    model = g.to_ising()
    jb = adjacency_weight_bits(model.n, model.nbr_idx, model.nbr_w)
    bk_dense = _mk("dense", model, n_trials=hp_small.n_trials,
                   noise="xorshift", field_mode="dense", j_mode="dense")
    bk_pc = _mk("dense", model, n_trials=hp_small.n_trials,
                noise="xorshift", field_mode="popcount")
    dense_j = memory.tree_device_bytes(bk_dense.J)
    packed_j = memory.tree_device_bytes(
        (bk_pc.packed_j.sign, bk_pc.packed_j.mags, bk_pc.packed_j.base)
    )
    emit(f"{csv_prefix}/j_bits", 0.0, f"{jb}")
    emit(f"{csv_prefix}/analytic_packed_j_bytes", 0.0,
         f"{packed_j_nbytes(model.n, jb)}")
    emit(f"{csv_prefix}/measured_j_bytes_dense", 0.0, f"{dense_j}")
    emit(f"{csv_prefix}/measured_j_bytes_packed", 0.0, f"{packed_j}")
    emit(f"{csv_prefix}/j_bytes_ratio", 0.0, f"{dense_j / packed_j:.2f}x")

    # Per-device residency under spin sharding (DESIGN.md §11): the same
    # engine state + problem arrays laid out over a spin mesh.  On 1 device
    # this reports the unsharded footprint; under a forced multi-device run
    # (XLA_FLAGS=--xla_force_host_platform_device_count=N) the busiest
    # device's share of the sharded leaves drops ~linearly in the mesh size
    # — the property the weak-scaling benchmark and test_spinshard gate.
    import jax as _jax

    from repro.core.engine import make_batched_backend
    from repro.sharding import spin_mesh

    n_dev = len(_jax.devices())
    mesh = spin_mesh(n_dev)
    bk_sh = make_batched_backend(
        "dense", n_bucket=1024, n_trials=hp_small.n_trials,
        noise="xorshift", partition="spin", mesh=mesh,
    )
    prob_sh = bk_sh.stack([model])
    st_sh = bk_sh.init_state(prob_sh, bk_sh.init_noise([0], [model.n]))
    per = memory.per_device_bytes((prob_sh, st_sh))
    total_sh = sum(per.values())
    busiest = memory.max_device_bytes((prob_sh, st_sh))
    emit(f"{csv_prefix}/spinshard_devices", 0.0, f"{n_dev}")
    emit(f"{csv_prefix}/spinshard_total_bytes", 0.0, f"{total_sh}")
    emit(f"{csv_prefix}/spinshard_max_device_bytes", 0.0, f"{busiest}")
    emit(f"{csv_prefix}/spinshard_balance", 0.0,
         f"{total_sh / (busiest * n_dev):.2f}" if busiest else "n/a")

    ok = measured_ratio >= (1.0 - RATIO_TOLERANCE) * ratio
    emit(f"{csv_prefix}/measured_vs_analytic_ok", 0.0, str(ok))
    return {
        "ratio": ratio,
        "m_ssa": m_ssa,
        "m_ha": m_ha,
        "measured_ratio": measured_ratio,
        "measured_ok": ok,
    }


if __name__ == "__main__":
    out = run()
    if not out["measured_ok"]:
        print(
            f"FAIL: measured HA-SSA/SSA ratio {out['measured_ratio']:.2f} "
            f"regressed >15% below the analytic model ({out['ratio']})",
            file=sys.stderr,
        )
        sys.exit(1)
