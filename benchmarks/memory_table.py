"""Paper Table IV: trajectory-memory usage, SSA (Eq. 5) vs HA-SSA (Eq. 6),
with equal cut values.

Table-II hyperparameters: N=800, I0 1→32 (6 plateaus), τ=100, m_shot=150:
SSA 0.48 Mb/iteration (72 Mb/trial) vs HA-SSA 0.08 Mb/iteration (12 Mb/trial)
→ 6×.  Also cross-checks the *structural* buffer sizes our scan actually
allocates (reduced run) against the closed-form model.
"""
from __future__ import annotations

from repro.core import SSAHyperParams, anneal, gset, memory

from .common import emit


def run(csv_prefix: str = "table4_memory"):
    hp = SSAHyperParams()  # Table II
    n = 800
    m_ssa = memory.ssa_bits_per_iteration(n, hp)
    m_ha = memory.hassa_bits_per_iteration(n, hp)
    ratio = memory.memory_ratio(hp)
    emit(f"{csv_prefix}/ssa_bits_per_iter", 0.0, f"{m_ssa}")
    emit(f"{csv_prefix}/hassa_bits_per_iter", 0.0, f"{m_ha}")
    emit(f"{csv_prefix}/ssa_Mb_per_iter", 0.0, f"{m_ssa/1e6:.2f}")
    emit(f"{csv_prefix}/hassa_Mb_per_iter", 0.0, f"{m_ha/1e6:.2f}")
    emit(f"{csv_prefix}/ratio", 0.0, f"{ratio}x")
    emit(f"{csv_prefix}/ssa_Mb_per_trial", 0.0,
         f"{memory.bits_per_trial(n, hp, hardware_aware=False)/1e6:.0f}")
    emit(f"{csv_prefix}/hassa_Mb_per_trial", 0.0,
         f"{memory.bits_per_trial(n, hp, hardware_aware=True)/1e6:.0f}")

    # Serving-layer honesty column: the service pads N to its power-of-two
    # shape bucket, so each stored bitplane carries dead pad bits.  Report
    # the waste next to the Eq. (5)/(6) numbers so the memory comparison
    # stays valid under bucketing (N=800 → bucket 1024 → 28% of each plane).
    from repro.core.engine import bucket_n

    for n_i in (800, 1024, 2000):
        nb = bucket_n(n_i)
        pad_bits = memory.padding_overhead_bits_per_iteration(n_i, hp)
        frac = memory.padding_overhead_fraction(n_i)
        emit(f"{csv_prefix}/bucket_n{n_i}", 0.0, f"{nb}")
        emit(f"{csv_prefix}/pad_overhead_bits_per_iter_n{n_i}", 0.0, f"{pad_bits}")
        emit(f"{csv_prefix}/pad_overhead_pct_n{n_i}", 0.0, f"{100*frac:.1f}")

    # structural witness at reduced scale: the XLA output buffers ARE the
    # memory model (DESIGN.md §4, BRAM → buffer shapes)
    g = gset.load("G11")
    hp_small = SSAHyperParams(n_trials=2, m_shot=2)
    r_ha = anneal(g, hp_small, seed=0, storage="i0max", record="traj")
    r_ssa = anneal(g, hp_small, seed=0, storage="all", record="traj")
    emit(f"{csv_prefix}/structural_ratio", 0.0,
         f"{r_ssa.stored_bits_per_iter // r_ha.stored_bits_per_iter}x")
    # equal-solution check (same stored-state subset contains the optimum)
    emit(f"{csv_prefix}/equal_best_cut", 0.0,
         str(int(r_ha.overall_best_cut) == int(r_ssa.overall_best_cut)))
    return {"ratio": ratio, "m_ssa": m_ssa, "m_ha": m_ha}


if __name__ == "__main__":
    run()
