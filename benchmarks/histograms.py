"""Paper Fig. 8 / Fig. 10: histograms of cut values over trials.

Key claim reproduced: HA-SSA's best/avg cut equals conventional SSA's
(identical update path, storage policy only), and both beat SA.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import SAHyperParams, SSAHyperParams, anneal, anneal_sa, gset

from .common import emit


def run(problems=("G11", "G12", "G13"), trials: int = 16, m_shot: int = 15,
        csv_prefix: str = "fig8_histogram"):
    out = {}
    for name in problems:
        p = gset.load(name)
        hp = SSAHyperParams(n_trials=trials, m_shot=m_shot)
        t0 = time.perf_counter()
        r_ha = anneal(p, hp, seed=1, storage="i0max", noise="xorshift",
                      track_energy=False)
        t_ha = (time.perf_counter() - t0) * 1e6
        r_ssa = anneal(p, hp, seed=1, storage="all", noise="xorshift",
                       track_energy=False)
        r_sa = anneal_sa(
            p, SAHyperParams(n_trials=trials, n_cycles=hp.total_cycles),
            seed=1, track_energy=False,
        )
        hist_ha, _ = np.histogram(r_ha.best_cut, bins=8)
        emit(f"{csv_prefix}/{name}/hassa", t_ha,
             f"best={r_ha.overall_best_cut};avg={r_ha.mean_best_cut:.1f};"
             f"hist={'|'.join(map(str, hist_ha))}")
        emit(f"{csv_prefix}/{name}/ssa", 0.0,
             f"best={r_ssa.overall_best_cut};avg={r_ssa.mean_best_cut:.1f}")
        emit(f"{csv_prefix}/{name}/sa", 0.0,
             f"best={r_sa.overall_best_cut};avg={r_sa.mean_best_cut:.1f}")
        eq = (r_ha.overall_best_cut == r_ssa.overall_best_cut
              and abs(r_ha.mean_best_cut - r_ssa.mean_best_cut) < 1e-9)
        emit(f"{csv_prefix}/{name}/hassa_equals_ssa", 0.0, str(eq))
        out[name] = (r_ha, r_ssa, r_sa)
    return out


if __name__ == "__main__":
    run()
