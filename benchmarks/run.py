"""Benchmark harness entry point: ``python -m benchmarks.run [--full]``.

One module per paper table/figure (see DESIGN.md §5):
  convergence  — Fig. 7/9    energy-vs-cycles, HA-SSA/SSA/SA
  histograms   — Fig. 8/10   cut-value distributions over trials
  memory_table — Table IV    Eq.(5)/(6) memory model + structural witness
  timing       — Table V     annealing time vs SA (+ HW models)
  pt_compare   — Table VII   vs parallel tempering
  equal_temp   — Fig. 12     equivalent-temperature-control comparison
  other_problems — Sec. VI-B  TSP / partitioning / graph isomorphism
  kernel_bench — (HW)        Pallas kernel timings + TPU projections
  roofline     — (framework) per-(arch×shape×mesh) roofline terms

Output: ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale trials/cycles (slow: ~100 trials × 90k cycles)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    args = ap.parse_args()

    from . import (convergence, equal_temp, histograms, kernel_bench,
                   memory_table, other_problems, pt_compare, roofline, timing)

    full = args.full
    jobs = {
        "memory_table": lambda: memory_table.run(),
        "convergence": lambda: convergence.run(
            trials=100 if full else 8, m_shot=150 if full else 20),
        "histograms": lambda: histograms.run(
            trials=100 if full else 16, m_shot=150 if full else 15),
        "timing": lambda: timing.run(
            trials=100 if full else 8, m_shot=150 if full else 10),
        "pt_compare": lambda: pt_compare.run(
            trials=100 if full else 8, m_shot=150 if full else 15),
        "equal_temp": lambda: equal_temp.run(trials=100 if full else 8),
        "other_problems": lambda: other_problems.run(),
        "kernel_bench": lambda: kernel_bench.run(),
        "roofline": lambda: roofline.run(),
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    for name, job in jobs.items():
        if only and name not in only:
            continue
        job()


if __name__ == "__main__":
    main()
