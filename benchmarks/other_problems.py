"""Paper Sec. VI-B: HA-SSA beyond ±1 MAX-CUT — integer weights / dense
connectivity (TSP, number partitioning, graph isomorphism).

Demonstrates the claim that HA-SSA inherits SSA's applicability to
integer-weight Ising models, with hyperparameters scale-matched to |J|
(core.problems.suggest_hyperparams).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import anneal
from repro.core.problems import (decode_gi, decode_partition, decode_tsp,
                                 gi_problem, partition_problem,
                                 suggest_hyperparams, tsp_problem,
                                 tsp_tour_length)

from .common import emit


def run(csv_prefix: str = "sec6b_problems"):
    # TSP: 5 cities on a line — optimum 2·span
    pts = np.array([0, 2, 3, 7, 11])
    dist = np.abs(pts[:, None] - pts[None, :])
    p = tsp_problem(dist, penalty=int(2 * dist.max()))
    hp = suggest_hyperparams(p.model, n_trials=16, m_shot=25)
    t0 = time.perf_counter()
    r = anneal(p.model, hp, seed=3, track_energy=False)
    us = (time.perf_counter() - t0) * 1e6
    tours = [decode_tsp(p, r.best_m[t]) for t in range(hp.n_trials)]
    lens = [tsp_tour_length(p, t) for t in tours if t is not None]
    emit(f"{csv_prefix}/tsp5", us,
         f"feasible={len(lens)}/16;best={min(lens) if lens else None};optimal=22")

    # number partitioning
    rng = np.random.default_rng(1)
    values = rng.integers(1, 10, size=16)
    model, _ = partition_problem(values)
    hp = suggest_hyperparams(model, n_trials=16, m_shot=15)
    t0 = time.perf_counter()
    r = anneal(model, hp, seed=0, track_energy=False)
    us = (time.perf_counter() - t0) * 1e6
    resid = min(decode_partition(values, r.best_m[t]) for t in range(16))
    emit(f"{csv_prefix}/partition16", us,
         f"residual={resid};parity_floor={int(values.sum()) % 2}")

    # graph isomorphism: 5-cycle vs relabeled 5-cycle
    n = 5
    A1 = np.zeros((n, n), dtype=int)
    for a in range(n):
        A1[a, (a + 1) % n] = A1[(a + 1) % n, a] = 1
    perm = np.array([2, 4, 1, 0, 3])
    inv = np.argsort(perm)
    A2 = A1[np.ix_(inv, inv)]
    model, _ = gi_problem(A1, A2)
    hp = suggest_hyperparams(model, n_trials=16, m_shot=20)
    t0 = time.perf_counter()
    r = anneal(model, hp, seed=1, track_energy=False)
    us = (time.perf_counter() - t0) * 1e6
    ok = 0
    for t in range(16):
        mapping = decode_gi(n, r.best_m[t])
        if mapping is None:
            continue
        P = np.zeros((n, n), dtype=int)
        P[np.arange(n), mapping] = 1
        if np.array_equal(P.T @ A1 @ P, A2):
            ok += 1
    emit(f"{csv_prefix}/gi5", us, f"valid_isomorphisms={ok}/16")


if __name__ == "__main__":
    run()
