"""Problem-frontend sweep: every family end-to-end through the service.

The paper demonstrates SSA/HA-SSA on G-set Max-Cut (and Sec. VI-B argues
the extension to integer-weight Ising models); the problem frontend
(:mod:`repro.problems`, DESIGN.md §9) opens generic QUBO, maximum
independent set, graph coloring and number partitioning through the same
:class:`~repro.serve.AnnealService`.  This benchmark is the end-to-end
witness:

* every family solves a smoke instance through the service on all three
  backends (sparse / dense / pallas), decodes to a domain solution, and the
  family's *feasibility verifier* must accept it — on every backend;
* the three backends must agree on the decoded objective (they run the
  same xorshift noise stream and are bit-identical per the engine
  property tests — a disagreement here is a frontend bug);
* ``hyperparams='auto'`` (local-energy-distribution autotuning,
  :mod:`repro.core.autotune`) must **match or beat** the hand-set defaults
  on the G11 cut and the QUBO smoke objective — the acceptance gate.

Writes ``BENCH_problems.json`` and exits 1 if any gate fails.

    python -m benchmarks.other_problems            # full sweep (nightly)
    python -m benchmarks.other_problems --smoke    # CI: reduced budgets
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from repro.core import SSAHyperParams, gset
from repro.problems import make_demo
from repro.serve import AnnealRequest, AnnealService

from .common import emit

BACKENDS = ("sparse", "dense", "pallas")

# family → (smoke size, full size) in frontend units (see FAMILIES factories).
SIZES = {
    "qubo": (32, 96),
    "mis": (48, 128),
    "coloring": (36, 90),
    "partition": (24, 48),
}


def _solve_one(backend, enc_or_problem, hp, *, seed=0, auto_base=None):
    svc = AnnealService(backend=backend, noise="xorshift")
    req = AnnealRequest(problem=enc_or_problem, hp=hp, seed=seed,
                        auto_base=auto_base)
    t0 = time.perf_counter()
    resp = svc.solve([req])[0]
    return resp, time.perf_counter() - t0


def run(smoke: bool = False, json_path: str = "BENCH_problems.json",
        csv_prefix: str = "problems"):
    base = (SSAHyperParams(n_trials=4, m_shot=2) if smoke
            else SSAHyperParams(n_trials=16, m_shot=10))
    report = {"smoke": smoke, "families": {}, "acceptance": {}}
    failures = []

    # -- family sweep: all backends, decoded-solution verification ---------
    for kind, (n_smoke, n_full) in SIZES.items():
        enc = make_demo(kind, n=n_smoke if smoke else n_full, seed=0)
        row = {"name": enc.model.name, "n_spins": enc.model.n, "backends": {}}
        objectives = {}
        for backend in BACKENDS:
            resp, wall = _solve_one(backend, enc, "auto", auto_base=base)
            rhp = resp.request.hp
            row["backends"][backend] = {
                "objective": resp.objective,
                "feasible": bool(resp.feasible),
                "wall_s": wall,
                "n_rnd": rhp.n_rnd,
                "i0_max": rhp.i0_max,
                "tau": rhp.tau,
            }
            objectives[backend] = resp.objective
            emit(f"{csv_prefix}/{kind}/{backend}", wall * 1e6,
                 f"objective={resp.objective};feasible={resp.feasible};"
                 f"n_rnd={rhp.n_rnd};i0_max={rhp.i0_max}")
            if not resp.feasible:
                failures.append(f"{kind}/{backend}: decoded solution infeasible")
        if len(set(objectives.values())) != 1:
            failures.append(f"{kind}: backends disagree: {objectives}")
        row["backends_agree"] = len(set(objectives.values())) == 1
        report["families"][kind] = row

    # -- acceptance: auto matches-or-beats hand on G11 and the QUBO case ---
    g11 = gset.load("G11")
    hand, _ = _solve_one("sparse", g11, base)
    auto, _ = _solve_one("sparse", g11, "auto", auto_base=base)
    g11_row = {
        "hand_cut": int(hand.result.overall_best_cut),
        "auto_cut": int(auto.result.overall_best_cut),
        "auto_params": {"n_rnd": auto.request.hp.n_rnd,
                        "i0_max": auto.request.hp.i0_max,
                        "tau": auto.request.hp.tau},
    }
    emit(f"{csv_prefix}/acceptance/g11", 0.0,
         f"hand={g11_row['hand_cut']};auto={g11_row['auto_cut']}")
    if g11_row["auto_cut"] < g11_row["hand_cut"]:
        failures.append(f"G11: auto cut {g11_row['auto_cut']} < "
                        f"hand cut {g11_row['hand_cut']}")
    report["acceptance"]["g11"] = g11_row

    qenc = make_demo("qubo", n=SIZES["qubo"][0], seed=0)  # the QUBO smoke case
    handq, _ = _solve_one("sparse", qenc, base)
    autoq, _ = _solve_one("sparse", qenc, "auto", auto_base=base)
    q_row = {"hand_objective": handq.objective, "auto_objective": autoq.objective}
    emit(f"{csv_prefix}/acceptance/qubo", 0.0,
         f"hand={q_row['hand_objective']};auto={q_row['auto_objective']}")
    if autoq.objective > handq.objective:  # minimization
        failures.append(f"qubo: auto objective {autoq.objective} > "
                        f"hand objective {handq.objective}")
    report["acceptance"]["qubo"] = q_row

    if smoke:
        # Structural witness for the 32-spin smoke regression (PR 7): on
        # tiny instances the resident pallas kernel's launch overhead loses
        # to the scan backends, so 'auto' must route them to dense.  Gate
        # the resolver itself — cheaper and less flaky than re-timing it.
        from repro.core.engine import MIN_RESIDENT_N, resolve_backend

        picked = resolve_backend("auto", 32)
        emit(f"{csv_prefix}/auto_backend_n32", 0.0,
             f"{picked};min_resident_n={MIN_RESIDENT_N}")
        if picked != "dense":
            failures.append(
                f"auto backend at n=32 resolved to {picked!r}, not 'dense' "
                f"(MIN_RESIDENT_N={MIN_RESIDENT_N} regression)"
            )
        report["acceptance"]["auto_backend_n32"] = picked

    report["failures"] = failures
    report["ok"] = not failures
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {json_path}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI: reduced instance sizes and cycle budgets")
    ap.add_argument("--json", default="BENCH_problems.json")
    args = ap.parse_args()
    rep = run(smoke=args.smoke, json_path=args.json)
    if not rep["ok"]:
        for f in rep["failures"]:
            print(f"FAIL: {f}", file=sys.stderr)
        sys.exit(1)
