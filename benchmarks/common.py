"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (harness contract).
``derived`` carries the paper-figure quantity (cut value, speedup, ratio…).
"""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time of fn(*args) in microseconds (block_until_ready)."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
