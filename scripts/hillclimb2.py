import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
"""Corrected-metrology hillclimb (round 2) + re-baseline of cells affected
by the loss-chunk / MoE-chunk counting fixes."""
import dataclasses, json, sys, traceback
sys.path.insert(0, "src")

import jax.numpy as jnp
from repro.launch.dryrun import run_cell
from repro.sharding import TRAIN_FSDP_SP_RULES
from repro.train.step import TrainConfig
from repro.optim.adamw import AdamWConfig

OUT = "experiments/perf"; os.makedirs(OUT, exist_ok=True)
BASE = "experiments/dryrun"

def save(rec, tag, out=OUT):
    path = os.path.join(out, f"{rec['arch']}__{rec['shape']}__{tag}.json")
    json.dump(rec, open(path, "w"), indent=1)
    if rec.get("status") == "ok" and "t_compute_s" in rec:
        print(f"== {tag}: tc={rec['t_compute_s']*1e3:.2f}ms tm={rec['t_memory_s']*1e3:.2f}ms "
              f"tx={rec['t_collective_s']*1e3:.2f}ms dom={rec['dominant']} "
              f"peak={rec['peak_bytes_per_device']/1e9:.1f}GB "
              f"useful={rec.get('useful_flops_ratio') or 0:.3f}", flush=True)

def mb(n):
    return TrainConfig(opt=AdamWConfig(), microbatches=n, grad_accum_dtype=jnp.bfloat16)

jobs = []
# --- re-baseline (metrology fix): all train cells + MoE prefill cells -----
for a in ("jamba-1.5-large-398b","granite-3-8b","mistral-large-123b","qwen3-1.7b",
          "qwen3-32b","olmoe-1b-7b","moonshot-v1-16b-a3b","rwkv6-3b",
          "whisper-tiny","phi-3-vision-4.2b"):
    jobs.append((lambda a=a: run_cell(a, "train_4k", "single"), "baseline", BASE,
                 f"{a}__train_4k__single"))
for a in ("olmoe-1b-7b","moonshot-v1-16b-a3b","jamba-1.5-large-398b"):
    jobs.append((lambda a=a: run_cell(a, "prefill_32k", "single"), "baseline", BASE,
                 f"{a}__prefill_32k__single"))

# --- revised variant ladders ----------------------------------------------
V = [
  # B: olmoe train_4k (einsum MoE kept; gather refuted in round 1)
  ("B1r_mb4+bf16grad", lambda: run_cell("olmoe-1b-7b","train_4k","single",
      rules_tag="B1r_mb4+bf16grad", train_cfg=mb(4))),
  ("B2r_mb4+bf16grad+sp", lambda: run_cell("olmoe-1b-7b","train_4k","single",
      rules_tag="B2r_mb4+bf16grad+sp", rules=TRAIN_FSDP_SP_RULES, train_cfg=mb(4))),
  ("B3r_mb2+bf16grad+rblk2", lambda: run_cell("olmoe-1b-7b","train_4k","single",
      rules_tag="B3r_mb2+bf16grad+rblk2", train_cfg=mb(2),
      cfg_transform=lambda c: dataclasses.replace(c, remat_block=2))),
  # C: mistral train_4k
  ("C1r_mb16", lambda: run_cell("mistral-large-123b","train_4k","single",
      rules_tag="C1r_mb16", train_cfg=mb(16))),
  ("C2r_mb4+fsdp_sp", lambda: run_cell("mistral-large-123b","train_4k","single",
      rules_tag="C2r_mb4+fsdp_sp", rules=TRAIN_FSDP_SP_RULES, train_cfg=mb(4))),
  ("C3r_mb4+fsdp_sp+rblk4", lambda: run_cell("mistral-large-123b","train_4k","single",
      rules_tag="C3r_mb4+fsdp_sp+rblk4", rules=TRAIN_FSDP_SP_RULES, train_cfg=mb(4),
      cfg_transform=lambda c: dataclasses.replace(c, remat_block=4))),
]
for tag, fn in V:
    jobs.append((fn, tag, OUT, None))

for fn, tag, out, fixed in jobs:
    try:
        rec = fn()
        name = fixed or f"{rec['arch']}__{rec['shape']}__{rec['rules']}"
        if fixed:
            json.dump(rec, open(os.path.join(out, fixed + ".json"), "w"), indent=1)
            if "t_compute_s" in rec:
                print(f"== rebase {fixed}: tc={rec['t_compute_s']*1e3:.2f}ms "
                      f"tm={rec['t_memory_s']*1e3:.2f}ms tx={rec['t_collective_s']*1e3:.2f}ms "
                      f"useful={rec.get('useful_flops_ratio') or 0:.3f}", flush=True)
        else:
            save(rec, rec["rules"], out)
    except Exception:
        traceback.print_exc(); print(f"{tag} FAILED", flush=True)
print("round 2 done", flush=True)
