import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Round 3: bf16-compute-params variants + corrected gather-MoE re-judgment."""
import dataclasses, json, sys, traceback
sys.path.insert(0, "src")
import jax.numpy as jnp
from repro.launch.dryrun import run_cell
from repro.sharding import TRAIN_FSDP_SP_RULES
from repro.train.step import TrainConfig
from repro.optim.adamw import AdamWConfig

OUT = "experiments/perf"; os.makedirs(OUT, exist_ok=True)

def mb(n, **kw):
    return TrainConfig(opt=AdamWConfig(), microbatches=n,
                       grad_accum_dtype=jnp.bfloat16, **kw)

V = [
  ("C4r_mb4+fsdp_sp+bf16compute", lambda: run_cell(
      "mistral-large-123b","train_4k","single",
      rules_tag="C4r_mb4+fsdp_sp+bf16compute", rules=TRAIN_FSDP_SP_RULES,
      train_cfg=mb(4, param_compute_dtype=jnp.bfloat16))),
  ("B4r_mb4+sp+bf16compute", lambda: run_cell(
      "olmoe-1b-7b","train_4k","single",
      rules_tag="B4r_mb4+sp+bf16compute", rules=TRAIN_FSDP_SP_RULES,
      train_cfg=mb(4, param_compute_dtype=jnp.bfloat16))),
  # re-judge gather-MoE with corrected metrology (mb=1, deployment chunking)
  ("B0r_gather_moe", lambda: run_cell(
      "olmoe-1b-7b","train_4k","single", rules_tag="B0r_gather_moe",
      cfg_transform=lambda c: dataclasses.replace(c, moe_impl="gather"))),
]
for tag, fn in V:
    try:
        rec = fn()
        path = os.path.join(OUT, f"{rec['arch']}__{rec['shape']}__{rec['rules']}.json")
        json.dump(rec, open(path, "w"), indent=1)
        if "t_compute_s" in rec:
            print(f"== {tag}: tc={rec['t_compute_s']*1e3:.2f}ms tm={rec['t_memory_s']*1e3:.2f}ms "
                  f"tx={rec['t_collective_s']*1e3:.2f}ms dom={rec['dominant']} "
                  f"peak={rec['peak_bytes_per_device']/1e9:.1f}GB "
                  f"useful={rec.get('useful_flops_ratio') or 0:.3f}", flush=True)
    except Exception:
        traceback.print_exc(); print(f"{tag} FAILED", flush=True)
print("round 3 done", flush=True)
