"""Import-sanity gate: every module under src/repro must import cleanly.

Walks the package tree and imports each module in a fresh interpreter-wide
pass (no subprocess per module — a broken transitive import fails here just
as it would for a user).  Run from the repo root:

    PYTHONPATH=src python scripts/check_imports.py

Used by the CI lint job; keeps lazy-import seams (repro.kernels loading
Pallas on demand, the hypothesis test stub, …) honest.
"""

from __future__ import annotations

import importlib
import pkgutil
import sys
import traceback


def main() -> int:
    import repro

    failures = []
    modules = sorted(
        m.name for m in pkgutil.walk_packages(repro.__path__, prefix="repro.")
    )
    for name in modules:
        try:
            importlib.import_module(name)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    print(f"imported {len(modules) - len(failures)}/{len(modules)} modules")
    if failures:
        print("FAILED imports:", *failures, sep="\n  ", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
