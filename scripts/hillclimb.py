import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
"""§Perf hillclimb driver: runs the variant ladder for the three chosen
cells and writes experiments/perf/<arch>__<shape>__<tag>.json.

Cells (from the baseline roofline table):
  A jamba-1.5-large-398b × long_500k  — worst roofline fraction (0.0016)
  B olmoe-1b-7b × train_4k            — most collective-bound (share 0.485)
  C mistral-large-123b × train_4k     — paper-representative: reduce stored
                                        intermediate state to fit HBM

Each variant is a (hypothesis, change) pair; see EXPERIMENTS.md §Perf for
the napkin math and confirm/refute log.
"""
import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import traceback  # noqa: E402

sys.path.insert(0, "src")

import jax.numpy as jnp  # noqa: E402

from repro.launch.dryrun import run_cell  # noqa: E402
from repro.sharding import (DEFAULT_RULES, SERVE_WEIGHT_STATIONARY_RULES,  # noqa: E402
                            TRAIN_FSDP_SP_RULES)
from repro.train.step import TrainConfig  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402

OUT = "experiments/perf"
os.makedirs(OUT, exist_ok=True)


def save(rec, tag):
    path = os.path.join(OUT, f"{rec['arch']}__{rec['shape']}__{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if rec.get("status") == "ok" and "t_compute_s" in rec:
        print(f"== {tag}: tc={rec['t_compute_s']*1e3:.2f}ms "
              f"tm={rec['t_memory_s']*1e3:.2f}ms tx={rec['t_collective_s']*1e3:.2f}ms "
              f"dom={rec['dominant']} peak={rec['peak_bytes_per_device']/1e9:.1f}GB "
              f"useful={rec.get('useful_flops_ratio') or 0:.3f}", flush=True)


VARIANTS = {
    # ---- Cell A: jamba long_500k (decode) --------------------------------
    "A1": lambda: run_cell(
        "jamba-1.5-large-398b", "long_500k", "single",
        rules_tag="A1_bf16_params", param_dtype=jnp.bfloat16),
    "A2": lambda: run_cell(
        "jamba-1.5-large-398b", "long_500k", "single",
        rules_tag="A2_bf16+weight_stationary",
        param_dtype=jnp.bfloat16, rules=SERVE_WEIGHT_STATIONARY_RULES),
    # ---- Cell B: olmoe train_4k ------------------------------------------
    "B1": lambda: run_cell(
        "olmoe-1b-7b", "train_4k", "single",
        rules_tag="B1_gather_moe",
        cfg_transform=lambda c: dataclasses.replace(c, moe_impl="gather")),
    "B2": lambda: run_cell(
        "olmoe-1b-7b", "train_4k", "single",
        rules_tag="B2_gather+mb4+bf16grad",
        cfg_transform=lambda c: dataclasses.replace(c, moe_impl="gather"),
        train_cfg=TrainConfig(opt=AdamWConfig(), microbatches=4,
                              grad_accum_dtype=jnp.bfloat16)),
    "B3": lambda: run_cell(
        "olmoe-1b-7b", "train_4k", "single",
        rules_tag="B3_gather+mb4+bf16grad+sp",
        cfg_transform=lambda c: dataclasses.replace(c, moe_impl="gather"),
        rules=TRAIN_FSDP_SP_RULES,
        train_cfg=TrainConfig(opt=AdamWConfig(), microbatches=4,
                              grad_accum_dtype=jnp.bfloat16)),
    # ---- Cell C: mistral train_4k ----------------------------------------
    "C1": lambda: run_cell(
        "mistral-large-123b", "train_4k", "single",
        rules_tag="C1_mb16",
        train_cfg=TrainConfig(opt=AdamWConfig(), microbatches=16,
                              grad_accum_dtype=jnp.bfloat16)),
    "C2": lambda: run_cell(
        "mistral-large-123b", "train_4k", "single",
        rules_tag="C2_mb16+fsdp_sp",
        rules=TRAIN_FSDP_SP_RULES,
        train_cfg=TrainConfig(opt=AdamWConfig(), microbatches=16,
                              grad_accum_dtype=jnp.bfloat16)),
    "C3": lambda: run_cell(
        "mistral-large-123b", "train_4k", "single",
        rules_tag="C3_mb4+fsdp_sp",
        rules=TRAIN_FSDP_SP_RULES,
        train_cfg=TrainConfig(opt=AdamWConfig(), microbatches=4,
                              grad_accum_dtype=jnp.bfloat16)),
}


def main():
    which = sys.argv[1:] or list(VARIANTS)
    for tag in which:
        try:
            rec = VARIANTS[tag]()
            save(rec, rec["rules"])
        except Exception:
            traceback.print_exc()
            print(f"variant {tag} FAILED", flush=True)


if __name__ == "__main__":
    main()
